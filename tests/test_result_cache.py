"""ResultCache correctness (api/cache.py + PDFSession integration): hits are
bitwise-identical and skip compute, result-defining spec changes (and
changed file manifests) miss, ExecSpec-only changes still hit."""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    ResultCache,
    SourceSpec,
    build_source,
)
from repro.core.executor import RESULT_FIELDS
from repro.data.file_source import export_cube

SMALL_SOURCE = SourceSpec(num_slices=6, lines_per_slice=9, points_per_line=12,
                          observations=200)


def spec_with_cache(cache_dir, source=SMALL_SOURCE, **method_kw):
    method_kw.setdefault("name", "grouping")
    return PipelineSpec(
        source=source,
        method=MethodSpec(**method_kw),
        compute=ComputeSpec(window_lines=3, num_bins=20),
        execution=ExecSpec(slices=(1, 2), cache_dir=str(cache_dir)),
    )


def assert_bitwise_equal(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    assert a.avg_error == b.avg_error
    assert a.spec_hash == b.spec_hash


def test_second_run_is_served_bitwise_identical(tmp_path):
    spec = spec_with_cache(tmp_path / "cache")
    s1 = PDFSession(spec)
    first = s1.run_all()
    rep1 = s1.report()
    assert rep1.cache_hits == 0 and rep1.cache_misses == 2
    assert not any(r.cached for r in first.values())

    s2 = PDFSession(spec)
    second = s2.run_all()
    rep2 = s2.report()
    assert rep2.cache_hits == 2 and rep2.cache_misses == 0
    for s in (1, 2):
        assert second[s].cached
        assert second[s].stats == []  # no window ran
        assert_bitwise_equal(first[s], second[s])
    # no executor was ever built: the cache-served session did zero compute
    assert not s2._executors
    assert rep2.windows == 0


def test_result_defining_change_misses(tmp_path):
    cache = tmp_path / "cache"
    PDFSession(spec_with_cache(cache)).run_all()
    changed = spec_with_cache(cache, group_tol=1e-3)
    s = PDFSession(changed)
    s.run_all()
    rep = s.report()
    assert rep.cache_hits == 0 and rep.cache_misses == 2


def test_exec_only_change_still_hits(tmp_path):
    cache = tmp_path / "cache"
    PDFSession(spec_with_cache(cache)).run_all()
    base = spec_with_cache(cache)
    staged = dataclasses.replace(
        base, execution=dataclasses.replace(
            base.execution, prefetch=False, async_persist=False, shards=2))
    s = PDFSession(staged)
    results = s.run_all()
    assert s.report().cache_hits == 2
    assert all(r.cached for r in results.values())


def test_changed_file_manifest_misses(tmp_path):
    cache = tmp_path / "cache"
    file_a = export_cube(SMALL_SOURCE, tmp_path / "cube_a", lines_per_chunk=4)
    file_b = export_cube(dataclasses.replace(SMALL_SOURCE, seed=5),
                         tmp_path / "cube_b", lines_per_chunk=4)
    PDFSession(spec_with_cache(cache, source=file_a)).run_all()

    hit = PDFSession(spec_with_cache(cache, source=file_a))
    hit.run_all()
    assert hit.report().cache_hits == 2

    # same knobs, different bytes on disk: the manifest sha keys the cache
    miss = PDFSession(spec_with_cache(cache, source=file_b))
    miss.run_all()
    assert miss.report().cache_hits == 0
    assert miss.report().cache_misses == 2


def test_error_bound_recomputed_on_hits(tmp_path):
    cache = tmp_path / "cache"
    spec = spec_with_cache(cache, error_bound=10.0)
    first = PDFSession(spec).run_all()
    second = PDFSession(spec).run_all()
    for s in (1, 2):
        assert first[s].error_bound_satisfied is True
        assert second[s].cached
        assert second[s].error_bound_satisfied is True


def test_cache_with_external_source_warns(tmp_path):
    sim = build_source(SMALL_SOURCE)
    spec = PipelineSpec(
        source=SourceSpec(kind="external"),
        compute=ComputeSpec(window_lines=3),
        execution=ExecSpec(cache_dir=str(tmp_path / "cache")),
    )
    with pytest.warns(UserWarning, match="external data source"):
        PDFSession(spec, data_source=sim)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a described source must not warn
        PDFSession(spec_with_cache(tmp_path / "c2"))


def test_misfiled_entry_is_a_miss(tmp_path):
    spec = spec_with_cache(tmp_path / "cache")
    s1 = PDFSession(spec)
    s1.run_all()
    cache = ResultCache(tmp_path / "cache")
    good = cache.lookup(spec.content_hash(), 1)
    assert good is not None and good.cached
    # an entry moved under the wrong hash directory must not be served
    wrong = tmp_path / "cache" / ("0" * 16)
    wrong.mkdir()
    cache.path(spec.content_hash(), 1).rename(wrong / "slice1.npz")
    assert cache.lookup("0" * 16, 1) is None


def test_cache_hit_still_persists_out_dir(tmp_path):
    """--cache-dir + --out-dir: a hit skips the executor but must still
    honor the out_dir contract (window .npz files + watermark), bitwise
    identical to what a computed run would have persisted."""
    import numpy as np

    cache = tmp_path / "cache"
    spec = spec_with_cache(cache)
    computed_out = tmp_path / "computed"
    with_out = dataclasses.replace(
        spec, execution=dataclasses.replace(spec.execution,
                                            out_dir=str(computed_out)))
    PDFSession(with_out).run_all()  # misses: executor persists normally

    cached_out = tmp_path / "cached"
    hit_spec = dataclasses.replace(
        spec, execution=dataclasses.replace(spec.execution,
                                            out_dir=str(cached_out)))
    s = PDFSession(hit_spec)
    s.run_all()
    assert s.report().cache_hits == 2

    computed_files = sorted(p.name for p in computed_out.iterdir())
    cached_files = sorted(p.name for p in cached_out.iterdir())
    assert cached_files == computed_files and cached_files
    for name in computed_files:
        if name.endswith(".npz"):
            a = np.load(computed_out / name)
            b = np.load(cached_out / name)
            assert sorted(a.files) == sorted(b.files)
            for k in a.files:
                np.testing.assert_array_equal(a[k], b[k], err_msg=f"{name}:{k}")

    # the persisted dir is a valid resume target for the same spec
    resumed = dataclasses.replace(
        hit_spec, execution=dataclasses.replace(hit_spec.execution,
                                                cache_dir=None))
    res = PDFSession(resumed).run_all(resume=True)
    assert all(len(r.stats) == 0 for r in res.values())  # nothing re-ran


def test_cache_hit_respects_resume_mismatch_check(tmp_path):
    """resume + cache hit + an out_dir watermarked by a DIFFERENT spec must
    raise the same resume-mismatch error the computed path raises — a hit
    must not quietly overwrite another computation's watermark."""
    cache = tmp_path / "cache"
    out = tmp_path / "out"
    other = spec_with_cache(tmp_path / "other_cache", group_tol=1e-3)
    other = dataclasses.replace(
        other, execution=dataclasses.replace(other.execution,
                                             out_dir=str(out)))
    PDFSession(other).run_all()  # out_dir now belongs to the other spec

    spec = spec_with_cache(cache)
    PDFSession(spec).run_all()  # populate the cache
    resuming = dataclasses.replace(
        spec, execution=dataclasses.replace(spec.execution,
                                            out_dir=str(out), resume=True))
    with pytest.raises(ValueError, match="resume mismatch"):
        PDFSession(resuming).run_all()


def test_corrupt_cache_entry_is_a_miss_and_recomputed(tmp_path):
    spec = spec_with_cache(tmp_path / "cache")
    first = PDFSession(spec).run_all()
    cache = ResultCache(tmp_path / "cache")
    entry = cache.path(spec.content_hash(), 1)
    entry.write_bytes(b"not a zip at all")  # truncated/partial sync

    s = PDFSession(spec)
    with pytest.warns(UserWarning, match="unreadable cache entry"):
        results = s.run_all()
    rep = s.report()
    assert rep.cache_hits == 1 and rep.cache_misses == 1  # slice 2 still hit
    assert not results[1].cached
    assert_bitwise_equal(first[1], results[1])
    # the recompute overwrote the bad entry: next run hits cleanly
    s2 = PDFSession(spec)
    s2.run_all()
    assert s2.report().cache_hits == 2


def test_sampling_results_cache_cleanly(tmp_path):
    spec = spec_with_cache(tmp_path / "cache", name="sampling",
                           sample_frac=0.5, sample_seed=3)
    first = PDFSession(spec).run_all()
    s2 = PDFSession(spec)
    second = s2.run_all()
    assert s2.report().cache_hits == 2
    for s in (1, 2):
        assert_bitwise_equal(first[s], second[s])
        # the -1 unsampled markers survive the round-trip
        assert (second[s].type_idx == -1).any()


# -- LRU size cap / shared-dir hygiene (serve-layer requirements) --------------

import os
import threading
import time as _time

from repro.core.executor import SliceResult


def fabricated(slice_i, spec_hash="lruhash", p=256):
    """A deterministic SliceResult per slice index — content is a pure
    function of ``slice_i`` so concurrent readers can verify bitwise."""
    rng = np.random.default_rng(1000 + slice_i)
    return SliceResult(
        type_idx=rng.integers(0, 4, p).astype(np.int32),
        params=rng.random((p, 3), dtype=np.float32),
        error=rng.random(p, dtype=np.float32),
        mean=rng.random(p, dtype=np.float32),
        std=rng.random(p, dtype=np.float32),
        skew=rng.random(p, dtype=np.float32),
        kurt=rng.random(p, dtype=np.float32),
        avg_error=float(slice_i),
        stats=[],
        slice_i=slice_i,
        spec_hash=spec_hash,
    )


def entry_size(tmp_path):
    probe = ResultCache(tmp_path / "probe")
    probe.store(fabricated(0))
    return probe.size_bytes()


def set_mtime(cache, slice_i, when, spec_hash="lruhash"):
    os.utime(cache.path(spec_hash, slice_i), (when, when))


def test_lru_cap_evicts_oldest_used(tmp_path):
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=2 * one + one // 2)
    now = _time.time()
    for i in (0, 1):
        cache.store(fabricated(i))
        set_mtime(cache, i, now - 100 + i)  # 0 is oldest-used
    cache.store(fabricated(2))  # over cap: oldest (0) must go
    assert cache.lookup("lruhash", 0) is None
    assert cache.lookup("lruhash", 1) is not None
    assert cache.lookup("lruhash", 2) is not None
    assert cache.evictions == 1
    assert cache.size_bytes() <= cache.max_bytes


def test_lookup_touch_refreshes_recency(tmp_path):
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=2 * one + one // 2)
    now = _time.time()
    for i in (0, 1):
        cache.store(fabricated(i))
        set_mtime(cache, i, now - 100 + i)
    # a hit on the *older* entry makes it the most recently used ...
    assert cache.lookup("lruhash", 0) is not None
    cache.store(fabricated(2))
    # ... so the cap evicts slice 1, not slice 0
    assert cache.lookup("lruhash", 1) is None
    assert cache.lookup("lruhash", 0) is not None


def test_store_never_evicts_its_own_entry(tmp_path):
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=max(1, one // 2))
    cache.store(fabricated(7))  # alone exceeds the cap
    assert cache.lookup("lruhash", 7) is not None
    cache.store(fabricated(8))  # evicts 7, keeps itself
    assert cache.lookup("lruhash", 7) is None
    assert cache.lookup("lruhash", 8) is not None


def test_session_wires_cache_max_bytes(tmp_path):
    spec = spec_with_cache(tmp_path / "cache")
    staged = dataclasses.replace(
        spec, execution=dataclasses.replace(spec.execution,
                                            cache_max_bytes=12345))
    assert PDFSession(staged).cache.max_bytes == 12345
    assert PDFSession(spec).cache.max_bytes is None
    # staging-only knob: both specs map to the same cache entries
    assert staged.content_hash() == spec.content_hash()


def test_stale_tmps_reaped_at_open_fresh_kept(tmp_path):
    d = tmp_path / "cache" / "somehash"
    d.mkdir(parents=True)
    stale = d / "dead-writer.tmp"
    fresh = d / "live-writer.tmp"
    stale.write_bytes(b"x")
    fresh.write_bytes(b"y")
    old = _time.time() - 7200
    os.utime(stale, (old, old))
    ResultCache(tmp_path / "cache")  # open reaps
    assert not stale.exists()
    assert fresh.exists()  # young tmp may belong to a live writer


def test_corrupt_entry_is_warned_miss_for_concurrent_readers(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    cache.store(fabricated(3))
    cache.path("lruhash", 3).write_bytes(b"garbage, not a zip")
    results, errors = [], []

    def reader():
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # not thread-safe to assert
                results.append(cache.lookup("lruhash", 3))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert results == [None] * 4  # every reader: clean miss, no crash
    with pytest.warns(UserWarning, match="unreadable cache entry"):
        assert cache.lookup("lruhash", 3) is None


def test_concurrent_store_lookup_under_eviction_pressure(tmp_path):
    """Two writer threads + two readers over one capped dir: no crashes,
    and every successful hit is bitwise-equal to that slice's content."""
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=3 * one + one // 2)
    expected = {i: fabricated(i) for i in range(8)}
    errors = []
    hits = [0]
    writers_done = threading.Event()

    def writer(offset):
        try:
            for round_ in range(6):
                for i in range(offset, 8, 2):
                    cache.store(expected[i])
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def reader():
        # keep polling until the writers finish (a fixed iteration count
        # can burn through every lookup before the first store lands and
        # see nothing but misses), with a floor so readers overlap each
        # other even if the writers are already done
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                round_ = 0
                while round_ < 40 or not writers_done.is_set():
                    got = cache.lookup("lruhash", round_ % 8)
                    if got is not None:
                        hits[0] += 1
                        assert_bitwise_equal(expected[got.slice_i], got)
                    round_ += 1
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    writers = [threading.Thread(target=writer, args=(0,)),
               threading.Thread(target=writer, args=(1,))]
    readers = [threading.Thread(target=reader),
               threading.Thread(target=reader)]
    for t in writers + readers:
        t.start()
    for t in writers:
        t.join()
    writers_done.set()
    for t in readers:
        t.join()
    assert not errors, errors[0]
    assert hits[0] > 0  # the readers did exercise the hit path
    # a last store's eviction pass skipped on sweep-lock contention can
    # leave the dir briefly over cap; one quiesced store re-trims exactly
    cache.store(expected[0])
    assert cache.size_bytes() <= cache.max_bytes
    assert cache.evictions > 0


# -- chunk-dependency fingerprints / adoption (streaming appends) --------------


DEPS = ("sha-a", "sha-b", "sha-c")


def test_store_records_deps_and_deps_roundtrip(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    cache.store(fabricated(0), deps=DEPS)
    cache.store(fabricated(1))  # no deps: predates tracking / non-file
    assert cache.deps("lruhash", 0) == DEPS
    assert cache.deps("lruhash", 1) is None
    assert cache.deps("lruhash", 9) is None  # missing entry
    # deps never leak into the served SliceResult
    got = cache.lookup("lruhash", 0)
    assert got is not None
    assert_bitwise_equal(fabricated(0), got)


def test_adopt_rekeys_matching_fingerprint_bitwise(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    cache.store(fabricated(2, spec_hash="oldhash"), deps=DEPS)
    assert cache.adopt("oldhash", "newhash", 2, DEPS)
    assert cache.adoptions == 1
    got = cache.lookup("newhash", 2)
    assert got is not None and got.spec_hash == "newhash"
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(
            getattr(fabricated(2), f), getattr(got, f), err_msg=f)
    # the adopted entry carries the deps forward, and the old entry
    # survives (adoption copies — other consumers may still hold old_hash)
    assert cache.deps("newhash", 2) == DEPS
    assert cache.lookup("oldhash", 2) is not None
    # idempotent: target already exists
    assert cache.adopt("oldhash", "newhash", 2, DEPS)
    assert cache.adoptions == 1


def test_adopt_refuses_unsound_rekeys(tmp_path):
    cache = ResultCache(tmp_path / "cache")
    cache.store(fabricated(3, spec_hash="oldhash"), deps=DEPS)
    cache.store(fabricated(4, spec_hash="oldhash"))  # no deps recorded
    # changed fingerprint: the slice's chunks were touched by the append
    assert not cache.adopt("oldhash", "newhash", 3, ("sha-a", "sha-CHANGED"))
    # no recorded deps: nothing proves the bytes are unchanged
    assert not cache.adopt("oldhash", "newhash", 4, DEPS)
    # empty expected fingerprint can prove nothing
    assert not cache.adopt("oldhash", "newhash", 3, ())
    # a plain missing source entry is a silent no (not a warning)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert not cache.adopt("ghosthash", "newhash", 3, DEPS)
    assert cache.adoptions == 0
    assert cache.lookup("newhash", 3) is None


# -- cross-process eviction coordination (two handles, one dir) ----------------


def test_foreign_sweep_lock_skips_eviction_pass(tmp_path):
    """Two processes sharing one cache_dir: while one holds the root
    ``.sweep.lock`` (a live eviction pass), the other's store skips its own
    sweep — counted as a lock miss, never a hang or a double-trim."""
    one = entry_size(tmp_path)
    a = ResultCache(tmp_path / "cache", max_bytes=2 * one + one // 2)
    b = ResultCache(tmp_path / "cache", max_bytes=2 * one + one // 2)
    now = _time.time()
    for i in (0, 1):
        a.store(fabricated(i))
        set_mtime(a, i, now - 100 + i)

    # handle b "is mid-sweep": a fresh root lock that a must not break
    sweep = tmp_path / "cache" / ".sweep.lock"
    sweep.write_text(str(os.getpid()))
    a.store(fabricated(2))  # over cap, but the sweep is foreign-held
    assert a.lock_misses == 1
    assert a.evictions == 0
    assert a.lookup("lruhash", 0) is not None  # nothing was trimmed

    sweep.unlink()  # the other process finished
    a.store(fabricated(3))  # now the pass runs and trims to the cap
    assert a.evictions > 0
    assert a.size_bytes() <= a.max_bytes
    assert b.lookup("lruhash", 3) is not None  # both handles stay coherent


def test_eviction_skips_entry_dir_locked_by_concurrent_store(tmp_path):
    """A per-entry ``.lock`` held by another process's in-flight store makes
    the evictor skip that entry this pass (lock miss), trimming others."""
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=one + one // 2)
    now = _time.time()
    cache.store(fabricated(0, spec_hash="hash_a"))
    os.utime(cache.path("hash_a", 0), (now - 100, now - 100))  # oldest
    # another process is mid-store into hash_a's dir: fresh .lock
    lock = tmp_path / "cache" / "hash_a" / ".lock"
    lock.write_text(str(os.getpid()))
    cache.store(fabricated(1, spec_hash="hash_b"))
    # hash_a was due for eviction but locked: skipped, counted, kept
    assert cache.lookup("hash_a", 0) is not None
    assert cache.lock_misses >= 1
    lock.unlink()
    cache.store(fabricated(2, spec_hash="hash_c"))
    assert cache.lookup("hash_a", 0) is None  # trimmed on the next pass
    assert cache.size_bytes() <= cache.max_bytes


def test_stale_sweep_lock_is_broken(tmp_path):
    """A ``.sweep.lock`` older than LOCK_STALE_SECONDS belongs to a dead
    process: the next eviction pass breaks it instead of skipping forever."""
    one = entry_size(tmp_path)
    cache = ResultCache(tmp_path / "cache", max_bytes=one + one // 2)
    cache.store(fabricated(0))
    sweep = tmp_path / "cache" / ".sweep.lock"
    sweep.write_text("12345")
    old = _time.time() - 3600
    os.utime(sweep, (old, old))
    cache.store(fabricated(1))  # breaks the dead lock, sweeps normally
    assert cache.evictions > 0
    assert cache.size_bytes() <= cache.max_bytes
