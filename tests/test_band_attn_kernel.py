"""Banded-attention Pallas kernel vs full-masked-attention oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.band_attn import banded_attention, banded_attention_ref

KEY = jax.random.PRNGKey(0)

CASES = [
    # (B, S, H, KV, hd, W)
    (2, 64, 4, 2, 16, 16),   # GQA
    (1, 48, 8, 8, 32, 16),   # MHA
    (2, 50, 4, 2, 16, 16),   # ragged tail (S % W != 0)
    (1, 128, 6, 2, 64, 32),  # wider head, G=3
    (1, 16, 2, 1, 8, 16),    # single block (S == W)
    (1, 8, 2, 1, 8, 16),     # S < W
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_band_attn_allclose(case, dtype):
    b, s, h, kv, hd, w = case
    q = (jax.random.normal(jax.random.fold_in(KEY, s), (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, s + 1), (b, s, kv, hd)) * 0.5).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, s + 2), (b, s, kv, hd)).astype(dtype)
    got = np.asarray(banded_attention(q, k, v, w), np.float32)
    ref = np.asarray(banded_attention_ref(q, k, v, w), np.float32)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, ref, atol=atol)


def test_band_attn_matches_block_local_layer():
    """Kernel == models.layers block-local path == full masked attention."""
    from repro.configs.base import ArchConfig
    from repro.models import layers as L

    cfg = ArchConfig("t", "dense", 2, 64, 4, 2, 16, 128, 256,
                     param_dtype=jnp.float32, compute_dtype=jnp.float32,
                     remat="none")
    p = L.init_attention(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 48, 64))
    pos = jnp.broadcast_to(jnp.arange(48), (2, 48))
    full = L.attention(p, x, cfg=cfg, positions=pos, window=16)
    blk = L.attention(p, x, cfg=cfg.replace(block_local_attn=True),
                      positions=pos, window=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blk), atol=2e-5)
