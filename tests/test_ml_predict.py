"""Decision tree: trainer correctness, array-predictor equivalence, tuning."""

import jax.numpy as jnp
import numpy as np

from repro.core import ml_predict as mlp


def _separable(n=600, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32) + 2 * (x[:, 1] > 0.5).astype(np.int32)
    return x, y


def test_tree_fits_separable_data():
    x, y = _separable()
    tree = mlp.train_tree(x, y, 4, depth=3, max_bins=32)
    assert mlp.model_error(tree, x, y) < 0.05


def test_tree_predict_is_vectorized_descent():
    """Array predictor == per-sample python descent."""
    x, y = _separable(200, seed=1)
    tree = mlp.train_tree(x, y, 4, depth=3, max_bins=16)
    arrays = tree.as_device()
    pred_vec = np.asarray(mlp.predict(arrays, jnp.asarray(x)))

    def descend(row):
        node = 0
        for _ in range(tree.depth):
            f, t = tree.feature[node], tree.threshold[node]
            node = 2 * node + 1 if row[f] <= t else 2 * node + 2
        return tree.leaf_label[node - (2**tree.depth - 1)]

    pred_ref = np.asarray([descend(r) for r in x])
    np.testing.assert_array_equal(pred_vec, pred_ref)


def test_single_class_tree():
    x = np.random.default_rng(0).normal(size=(50, 2)).astype(np.float32)
    y = np.full(50, 2, np.int32)
    tree = mlp.train_tree(x, y, 4, depth=3)
    assert mlp.model_error(tree, x, y) == 0.0


def test_depth_one_tree():
    x, _ = _separable(300)
    y = (x[:, 0] > 0).astype(np.int32)
    tree = mlp.train_tree(x, y, 2, depth=1, max_bins=64)
    assert mlp.model_error(tree, x, y) < 0.05


def test_tune_hyperparameters_returns_valid():
    x, y = _separable(400, seed=3)
    depth, bins, err = mlp.tune_hyperparameters(
        x, y, 4, depths=(1, 2, 3), bins=(8, 16), seed=0
    )
    assert depth in (1, 2, 3) and bins in (8, 16)
    assert 0 <= err <= 1
    # separable data: the tuned model should be decent
    assert err < 0.2


def test_distribution_type_classification_from_moments():
    """The paper's actual use: classify distribution type from (mu, sigma).
    Construct types whose (mu, sigma) signatures separate."""
    rng = np.random.default_rng(0)
    feats, labels = [], []
    for i, (mu, sig) in enumerate([(0, 1), (5, 1), (0, 3), (5, 3)]):
        f = np.stack(
            [rng.normal(mu, 0.05, 300), rng.normal(sig, 0.05, 300)], axis=1
        )
        feats.append(f)
        labels.append(np.full(300, i))
    x = np.concatenate(feats).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    tree = mlp.train_tree(x, y, 4, depth=3, max_bins=32)
    assert mlp.model_error(tree, x, y) < 0.02
