"""benchmarks/run.py --check gate logic (drift normalization + retry
plumbing): pure-function tests — the heavy measurement paths are exercised
by the CI gate itself."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import run as bench_run  # noqa: E402


def test_check_regressions_names_only_reproducible_breaches():
    committed = {
        "kernel/a": 10000.0,
        "kernel/b": 10000.0,
        "fig06/x": 8000.0,
        "kernel/tiny": 100.0,  # below GATE_MIN_US: never gated
        "fig10/overlap": 9000.0,  # non-gated prefix
    }
    fresh = {
        "kernel/a": 10500.0,  # 1.05x: absorbed by drift
        "kernel/b": 26000.0,  # 2.6x: a real regression
        "fig06/x": 8300.0,
        "kernel/tiny": 5000.0,  # 50x but sub-noise-floor
        "fig10/overlap": 90000.0,  # 10x but untracked
    }
    assert bench_run.check_regressions(fresh, committed) == ["kernel/b"]


def test_check_regressions_ok_returns_empty_list():
    committed = {"kernel/a": 10000.0, "kernel/b": 20000.0}
    fresh = {"kernel/a": 11000.0, "kernel/b": 22000.0}
    assert bench_run.check_regressions(fresh, committed) == []


def test_check_regressions_vacuous_gate_is_none():
    # nothing measured, or nothing gated in the committed map: the gate must
    # not silently pass (main exits 2 on None)
    assert bench_run.check_regressions({}, {"kernel/a": 10000.0}) is None
    assert bench_run.check_regressions({"kernel/a": 1.0}, {"fig10/x": 9000.0}) is None


def test_drift_normalization_forgives_machine_phase():
    """A uniform 1.4x machine slowdown (shared-runner phase) fails nothing."""
    committed = {f"kernel/{i}": 10000.0 for i in range(5)}
    fresh = {f"kernel/{i}": 14000.0 for i in range(5)}
    assert bench_run.check_regressions(fresh, committed) == []


@pytest.mark.parametrize("threshold_attr", ["GATE_MAX_REGRESSION", "GATE_MIN_US"])
def test_gate_constants_exist(threshold_attr):
    assert getattr(bench_run, threshold_attr) > 0
