"""File-backed cube sources (data/file_source.py): export/read round-trip,
manifest content hashing, spec integration (kind='file'), and full-pipeline
bitwise fidelity vs the simulation the cube was exported from."""

import dataclasses
import json
import shutil

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    SourceSpec,
    build_source,
    source_spec_for,
)
from repro.core.regions import Window
from repro.data.file_source import (
    FileCubeSource,
    LAYOUTS,
    export_cube,
    manifest_sha,
    read_manifest,
)
from repro.data.loader import ThrottledSource

from repro.core.executor import RESULT_FIELDS

SIM_SOURCE = SourceSpec(num_slices=4, lines_per_slice=9, points_per_line=11,
                        observations=120)


@pytest.fixture(scope="module")
def cube(tmp_path_factory):
    """One exported cube shared by the module: (sim spec, file spec, dir)."""
    d = tmp_path_factory.mktemp("cube")
    file_spec = export_cube(SIM_SOURCE, d, lines_per_chunk=4)
    return SIM_SOURCE, file_spec, d


def test_layouts_mirror_spec_constant():
    from repro.api.spec import FILE_LAYOUTS

    assert FILE_LAYOUTS == LAYOUTS


def test_export_returns_runnable_file_spec(cube):
    _, file_spec, d = cube
    assert file_spec.kind == "file" and file_spec.path == str(d)
    # advisory geometry filled from the actual cube
    assert file_spec.num_slices == 4 and file_spec.lines_per_slice == 9
    assert file_spec.points_per_line == 11 and file_spec.observations == 120
    src = build_source(file_spec)
    assert isinstance(src, FileCubeSource)
    assert src.geometry.num_slices == 4


def test_window_reads_match_simulation_bitwise(cube):
    sim_spec, file_spec, _ = cube
    sim = build_source(sim_spec)
    src = build_source(file_spec)
    # windows inside one chunk, spanning the chunk boundary at line 4,
    # spanning two boundaries, and the ragged tail chunk (lines 8..9)
    for w in (Window(0, 0, 3), Window(1, 2, 6), Window(2, 0, 9),
              Window(3, 7, 9), Window(3, 8, 9)):
        got = src.load_window(w)
        want = sim.load_window(w)
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, want)


def test_window_bounds_validated(cube):
    _, file_spec, _ = cube
    src = build_source(file_spec)
    with pytest.raises(ValueError, match="outside cube"):
        src.load_window(Window(4, 0, 3))
    with pytest.raises(ValueError, match="outside cube"):
        src.load_window(Window(0, 5, 12))


def test_manifest_sha_is_location_independent(cube, tmp_path):
    _, file_spec, d = cube
    moved = tmp_path / "moved"
    shutil.copytree(d, moved)
    assert manifest_sha(moved) == manifest_sha(d)
    spec_a = PipelineSpec(source=file_spec)
    spec_b = PipelineSpec(source=dataclasses.replace(file_spec,
                                                     path=str(moved)))
    assert spec_a.content_hash() == spec_b.content_hash()


def test_different_data_different_manifest_sha(cube, tmp_path):
    _, _, d = cube
    other = export_cube(dataclasses.replace(SIM_SOURCE, seed=1),
                        tmp_path / "other", lines_per_chunk=4)
    assert manifest_sha(other.path) != manifest_sha(d)


def test_advisory_fields_do_not_change_file_hash(cube):
    _, file_spec, _ = cube
    a = PipelineSpec(source=file_spec)
    b = PipelineSpec(source=dataclasses.replace(file_spec, seed=99,
                                                observations=7))
    assert a.content_hash() == b.content_hash()


def test_hand_edited_manifest_cannot_keep_its_sha(cube, tmp_path):
    _, _, d = cube
    tampered = tmp_path / "tampered"
    shutil.copytree(d, tampered)
    m = json.loads((tampered / "manifest.json").read_text())
    m["chunks"][0]["sha256"] = "0" * 64  # forged chunk hash, stored sha kept
    (tampered / "manifest.json").write_text(json.dumps(m))
    assert manifest_sha(tampered) != manifest_sha(d)


def test_verify_catches_corrupt_chunk(cube, tmp_path):
    _, _, d = cube
    bad = tmp_path / "bad"
    shutil.copytree(d, bad)
    name = read_manifest(bad)["chunks"][0]["file"]
    arr = np.load(bad / name)
    arr = arr.copy()
    arr.flat[0] += 1.0
    np.save(bad / name, arr)
    FileCubeSource(d).verify()  # pristine cube passes
    with pytest.raises(ValueError, match="corrupt"):
        FileCubeSource(bad).verify()


def test_manifest_with_coverage_gap_rejected(cube, tmp_path):
    """A manifest whose chunks don't tile a slice must be refused up front
    — load_window would otherwise return uninitialized buffer rows for the
    uncovered lines."""
    _, _, d = cube
    gappy = tmp_path / "gappy"
    shutil.copytree(d, gappy)
    m = json.loads((gappy / "manifest.json").read_text())
    dropped = [c for c in m["chunks"]
               if not (c["slice"] == 1 and c["line_start"] == 4)]
    assert len(dropped) == len(m["chunks"]) - 1
    m["chunks"] = dropped
    (gappy / "manifest.json").write_text(json.dumps(m))
    with pytest.raises(ValueError, match="does not cover slice 1"):
        FileCubeSource(gappy)


def test_missing_manifest_is_a_clear_error(tmp_path):
    with pytest.raises(ValueError, match="export_cube"):
        FileCubeSource(tmp_path)
    spec = PipelineSpec(source=SourceSpec(kind="file", path=str(tmp_path)))
    with pytest.raises(ValueError, match="export_cube"):
        spec.content_hash()


def test_throttled_file_source(cube):
    _, file_spec, _ = cube
    throttled = dataclasses.replace(file_spec, throttle_mb_s=1000.0)
    src = build_source(throttled)
    assert isinstance(src, ThrottledSource)
    assert isinstance(src.inner, FileCubeSource)
    # the throttle is an execution-time model, not a data identity change
    assert (PipelineSpec(source=throttled).content_hash()
            == PipelineSpec(source=file_spec).content_hash())
    # source_spec_for round-trips the wrapped reader, advisory geometry
    # filled from the manifest (like export_cube's returned spec)
    back = source_spec_for(src)
    assert back.kind == "file" and back.path == file_spec.path
    assert back.throttle_mb_s == pytest.approx(1000.0)
    assert (back.num_slices, back.lines_per_slice, back.points_per_line,
            back.observations) == (4, 9, 11, 120)


def test_file_spec_json_roundtrip(cube):
    _, file_spec, _ = cube
    spec = PipelineSpec(source=file_spec,
                        method=MethodSpec(name="grouping"),
                        compute=ComputeSpec(window_lines=3, num_bins=20))
    back = PipelineSpec.from_json(spec.to_json())
    assert back == spec
    assert back.content_hash() == spec.content_hash()


def test_build_source_external_error_points_at_file_path():
    with pytest.raises(ValueError, match="export_cube"):
        build_source(SourceSpec(kind="external"))


@pytest.mark.parametrize("build", [
    lambda: SourceSpec(kind="file"),  # path required
    lambda: SourceSpec(path="/somewhere"),  # path only for kind='file'
    lambda: SourceSpec(kind="external", path="/somewhere"),
    lambda: SourceSpec(kind="file", path="/somewhere", layout="columnar"),
])
def test_invalid_file_specs_rejected(build):
    with pytest.raises(ValueError):
        build()


def test_pipeline_results_bitwise_identical_to_simulation(cube):
    """The acceptance round-trip: export_cube(sim_spec) then running the
    same pipeline with kind='file' yields bitwise-identical SliceResults."""
    sim_spec, file_spec, _ = cube
    knobs = dict(method=MethodSpec(name="grouping"),
                 compute=ComputeSpec(window_lines=4, num_bins=20))
    r_sim = PDFSession(PipelineSpec(source=sim_spec, **knobs)).run_all([2])[2]
    r_file = PDFSession(PipelineSpec(source=file_spec, **knobs)).run_all([2])[2]
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(r_sim, f), getattr(r_file, f),
                                      err_msg=f)
    assert r_sim.avg_error == r_file.avg_error
    # the two runs are distinct computations provenance-wise: one is
    # identified by generator knobs, the other by the bytes on disk
    assert r_sim.spec_hash != r_file.spec_hash


def test_prefetched_file_run_matches_serial(cube):
    _, file_spec, _ = cube
    base = PipelineSpec(source=file_spec, compute=ComputeSpec(window_lines=3))
    serial = dataclasses.replace(
        base, execution=ExecSpec(prefetch=False, async_persist=False))
    r_pre = PDFSession(base).run_all([1])[1]
    r_ser = PDFSession(serial).run_all([1])[1]
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(r_pre, f), getattr(r_ser, f))


# -- overwrite guard / versioned manifests (streaming, DESIGN.md §16) ----------


def test_export_refuses_to_clobber_live_cube(tmp_path):
    """Re-exporting over an existing cube would silently re-key every spec
    hash derived from it: refused unless overwrite=True, and the refusal
    happens before ANY chunk is written — the old cube survives untouched."""
    d = tmp_path / "cube"
    export_cube(SIM_SOURCE, d, lines_per_chunk=4)
    before_manifest = (d / "manifest.json").read_bytes()
    before_files = sorted(p.name for p in d.iterdir())

    other = dataclasses.replace(SIM_SOURCE, seed=99)
    with pytest.raises(FileExistsError, match="overwrite=True"):
        export_cube(other, d, lines_per_chunk=4)
    # nothing changed: same file set, manifest byte-identical
    assert sorted(p.name for p in d.iterdir()) == before_files
    assert (d / "manifest.json").read_bytes() == before_manifest

    # explicit overwrite replaces the cube (and re-keys its sha)
    old_sha = manifest_sha(d)
    export_cube(other, d, lines_per_chunk=4, overwrite=True)
    assert manifest_sha(d) != old_sha


def test_export_into_manifestless_dir_is_allowed(tmp_path):
    """A directory without a manifest (a crashed export's leftovers, or
    just a plain dir) is not a cube — no guard, export proceeds."""
    d = tmp_path / "cube"
    d.mkdir()
    (d / "stray.txt").write_text("not a cube")
    spec = export_cube(SIM_SOURCE, d, lines_per_chunk=4)
    assert build_source(spec).geometry.num_slices == 4


def test_versioned_manifest_reads(tmp_path):
    from repro.data.file_source import manifest_version
    from repro.streaming import append_realizations

    d = tmp_path / "cube"
    export_cube(SIM_SOURCE, d, lines_per_chunk=4)
    assert manifest_version(d) == 1
    sha1 = manifest_sha(d)
    block = np.zeros((SIM_SOURCE.lines_per_slice, SIM_SOURCE.points_per_line,
                      3), np.float32)
    assert append_realizations(d, {0: block}) == 2
    assert manifest_version(d) == 2
    # version pinning: the archived manifest is still addressable, and its
    # sha is exactly what the live manifest hashed to before the append
    assert manifest_sha(d, version=1) == sha1
    assert manifest_sha(d) != sha1
    assert read_manifest(d, version=1).get("version", 1) == 1
    assert read_manifest(d, version=2)["version"] == 2
    with pytest.raises(ValueError, match="no version 7"):
        read_manifest(d, version=7)
