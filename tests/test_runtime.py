"""Straggler monitor + elastic re-mesh planning."""

import pytest

from repro.runtime import ElasticPlan, StepMonitor, StragglerPolicy, plan_remesh


def test_straggler_flagging_with_synthetic_clock():
    mon = StepMonitor(StragglerPolicy(window=16, threshold=3.0, min_samples=3,
                                      grace_seconds=0.0))
    t = 0.0
    for i in range(5):  # five 1-second units establish the median
        mon.start(f"u{i}", now=t)
        mon.finish(f"u{i}", now=t + 1.0)
        t += 1.0
    mon.start("slow", now=t)
    assert mon.check_stragglers(now=t + 2.0) == []  # under 3x median
    assert mon.check_stragglers(now=t + 3.5) == ["slow"]
    assert "slow" in mon.flagged


def test_no_flags_before_min_samples():
    mon = StepMonitor(StragglerPolicy(min_samples=5, grace_seconds=0.0))
    mon.start("a", now=0.0)
    mon.finish("a", now=1.0)
    mon.start("b", now=1.0)
    assert mon.check_stragglers(now=100.0) == []


def test_monitor_median():
    mon = StepMonitor(StragglerPolicy(min_samples=3))
    for i, dur in enumerate([1.0, 5.0, 2.0]):
        mon.start(f"u{i}", now=0.0)
        mon.finish(f"u{i}", now=dur)
    assert mon.median() == 2.0


def test_plan_remesh_node_loss():
    old = ElasticPlan(data=16, model=16, pods=1, grad_accum=1)
    # lose 16 devices: 240 healthy -> best grid with model divisor 16 is 15x16
    plan = plan_remesh(240, model_divisors=(16, 8, 4), target_global_batch=256, old_plan=old)
    assert plan.model == 16 and plan.data == 15
    assert plan.devices == 240
    assert plan.grad_accum >= 2  # keeps global batch via accumulation


def test_plan_remesh_prefers_larger_model_axis_on_tie():
    old = ElasticPlan(data=4, model=4, pods=1, grad_accum=1)
    plan = plan_remesh(16, model_divisors=(8, 4, 2), target_global_batch=64, old_plan=old)
    assert plan.devices == 16
    assert plan.model == 8


def test_plan_remesh_impossible_raises():
    old = ElasticPlan(data=1, model=1, pods=1, grad_accum=1)
    with pytest.raises(ValueError):
        plan_remesh(1, model_divisors=(8,), target_global_batch=8, old_plan=old)
