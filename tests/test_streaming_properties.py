"""Property tests for the streaming merge math (streaming/moments.py).

All comparisons are same-precision (float64 merge tree vs float64 merge
tree, or float64 merge vs float64 from-scratch): comparing a merge against
the *pipeline's* float32 single-pass path mixes in the pipeline's own
cancellation noise, which is unbounded on adversarial ill-conditioned
inputs — that cross-precision regime is covered by the e2e test on real
cube data (test_streaming.py), not by adversarial property search.

The tolerance is the PINNED ``MERGE_ULP_BUDGET`` constant — never a value
recomputed from an observed run.

Derandomization and per-example deadlines come from the hypothesis
profiles registered in conftest.py ("ci" is the default; set
HYPOTHESIS_PROFILE=dev for randomized local exploration).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.streaming import (
    MERGE_ULP_BUDGET,
    empty_suffstats,
    merge_counts,
    merge_suffstats,
    moments_from_suffstats,
    suffstats_from_values,
    ulp_diff,
)

# Partitions of well-conditioned float32 observations: bounded magnitude
# and a floor on the partition size keep the *reference* side (a single
# float64 pass) meaningful — the budget bounds merge-tree rounding, not
# catastrophic cancellation both sides would share.
values = st.floats(-100.0, 100.0, allow_nan=False, width=32)


def partition(min_size=1, max_size=24):
    return st.lists(values, min_size=min_size, max_size=max_size)


def to_arr(part):
    return np.asarray(part, np.float32).reshape(1, -1)


def assert_moments_close(a, b):
    ma, mb = moments_from_suffstats(a), moments_from_suffstats(b)
    for name in ("mean", "var", "skew", "kurt", "vmin", "vmax"):
        va = np.asarray(getattr(ma, name))
        vb = np.asarray(getattr(mb, name))
        # ulp distance degenerates across zero (every float between -x and
        # +x counts), so near-cancelled moments get an absolute floor of
        # one f32 epsilon — noise below representable granularity at unit
        # scale is "equal" for a float32 pipeline.
        ok = (ulp_diff(va, vb) <= MERGE_ULP_BUDGET) | (np.abs(va - vb) <= 2.0**-23)
        assert ok.all(), f"{name}: {ulp_diff(va, vb).max()} ulps over budget"


@settings(max_examples=200)
@given(partition(), partition(), partition())
def test_merge_is_associative(p1, p2, p3):
    a, b, c = (suffstats_from_values(to_arr(p)) for p in (p1, p2, p3))
    left = merge_suffstats(merge_suffstats(a, b), c)
    right = merge_suffstats(a, merge_suffstats(b, c))
    assert left.n == right.n
    np.testing.assert_array_equal(left.vmin, right.vmin)  # min/max exact
    np.testing.assert_array_equal(left.vmax, right.vmax)
    assert_moments_close(left, right)


@settings(max_examples=200)
@given(st.lists(partition(), min_size=2, max_size=5), st.randoms())
def test_merge_is_permutation_invariant(parts, rnd):
    stats = [suffstats_from_values(to_arr(p)) for p in parts]
    inorder = stats[0]
    for s in stats[1:]:
        inorder = merge_suffstats(inorder, s)
    shuffled = list(stats)
    rnd.shuffle(shuffled)
    other = shuffled[0]
    for s in shuffled[1:]:
        other = merge_suffstats(other, s)
    assert inorder.n == other.n
    assert_moments_close(inorder, other)


@settings(max_examples=200)
@given(st.lists(partition(), min_size=1, max_size=4))
def test_merge_tree_matches_from_scratch(parts):
    merged = suffstats_from_values(to_arr(parts[0]))
    for p in parts[1:]:
        merged = merge_suffstats(merged, suffstats_from_values(to_arr(p)))
    direct = suffstats_from_values(
        np.concatenate([to_arr(p) for p in parts], axis=-1))
    assert merged.n == direct.n
    np.testing.assert_array_equal(merged.vmin, direct.vmin)
    np.testing.assert_array_equal(merged.vmax, direct.vmax)
    assert_moments_close(merged, direct)


@settings(max_examples=100)
@given(partition())
def test_empty_partition_is_identity(p):
    s = suffstats_from_values(to_arr(p))
    e = empty_suffstats(s.mean.shape)
    for left, right in ((merge_suffstats(e, s), s),
                        (merge_suffstats(s, e), s)):
        assert left.n == right.n
        for f_l, f_r in zip(left[1:], right[1:]):
            np.testing.assert_array_equal(f_l, f_r)


@settings(max_examples=100)
@given(values, partition(min_size=2), partition(min_size=2))
def test_degenerate_constant_partitions_stay_finite(c, p1, p2):
    const1 = np.full((1, len(p1)), np.float32(c))
    const2 = np.full((1, len(p2)), np.float32(c))
    merged = merge_suffstats(suffstats_from_values(const1),
                             suffstats_from_values(const2))
    m = moments_from_suffstats(merged)
    for f in m:
        assert np.isfinite(np.asarray(f)).all()
    np.testing.assert_array_equal(np.asarray(m.vmin), np.float32(c))
    np.testing.assert_array_equal(np.asarray(m.vmax), np.float32(c))


bins = st.integers(1, 16)


@settings(max_examples=200)
@given(bins, st.data())
def test_histogram_merge_exact_and_order_free(num_bins, data):
    """Per-partition integer bin counts (same fixed edges) merge exactly —
    elementwise int64 sums — in any order and association."""
    count_arr = st.lists(st.integers(0, 2**23), min_size=num_bins,
                         max_size=num_bins)
    parts = [np.asarray(data.draw(count_arr), np.int64) for _ in range(3)]
    fwd = merge_counts(merge_counts(parts[0], parts[1]), parts[2])
    rev = merge_counts(parts[2], merge_counts(parts[1], parts[0]))
    np.testing.assert_array_equal(fwd, sum(parts))
    np.testing.assert_array_equal(rev, sum(parts))
