"""Per-architecture smoke tests (REDUCED configs, CPU, 1 device):
instantiate, one forward/train step, one prefill+decode step; assert output
shapes and no NaNs. The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import encdec as ED
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _extras(cfg):
    if cfg.family == "vlm":
        return {"memory": jax.random.normal(KEY, (B, cfg.num_patches, cfg.d_model))}
    return None


@pytest.mark.parametrize("arch", registry.names())
def test_reduced_train_step(arch):
    cfg = registry.get(arch).reduced()
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        p = ED.init_params(cfg, KEY)
        frames = jax.random.normal(KEY, (B, S, cfg.d_model))
        loss, grads = jax.value_and_grad(
            lambda q: ED.loss_fn(q, frames, toks, toks, cfg)
        )(p)
    else:
        p = T.init_params(cfg, KEY)
        extras = _extras(cfg)
        logits = T.forward(p, toks, cfg, extras)
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), "NaN in forward"
        loss, grads = jax.value_and_grad(
            lambda q: T.loss_fn(q, toks, toks, cfg, extras)
        )(p)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), (
        f"{arch}: NaN grads"
    )


@pytest.mark.parametrize("arch", registry.names())
def test_reduced_serve_step(arch):
    cfg = registry.get(arch).reduced()
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        p = ED.init_params(cfg, KEY)
        frames = jax.random.normal(KEY, (B, S, cfg.d_model))
        logits, cache = ED.prefill(p, frames, toks, cfg, max_len=S + 4)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = ED.decode_step(p, nxt, cache, S, cfg)
    else:
        p = T.init_params(cfg, KEY)
        extras = _extras(cfg)
        logits, cache = T.prefill(p, toks, cfg, extras, max_len=S + 4)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = T.decode_step(p, nxt, cache, S, cfg, extras)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", registry.names())
def test_full_config_structure_is_consistent(arch):
    """Full configs: structural invariants only (no allocation)."""
    cfg = registry.get(arch)
    assert cfg.num_repeats >= 1
    if cfg.family != "encdec":
        assert len(cfg.prefix) + len(cfg.pattern) * cfg.num_repeats == cfg.num_layers
    if cfg.q_heads:
        assert cfg.q_heads % max(cfg.kv_heads, 1) == 0, "GQA group must divide"
    if cfg.num_experts:
        assert 0 < cfg.moe_top_k <= cfg.num_experts
    # eval_shape init must succeed without allocating
    init = ED.init_params if cfg.family == "encdec" else T.init_params
    struct = jax.eval_shape(lambda: init(cfg, KEY))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(struct))
    assert n_params > 0
