"""Checkpoint manager: roundtrip, retention, async, crash-safety."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)},
        "opt": [jnp.zeros(2), jnp.full((2, 2), 7.0)],
    }


def test_roundtrip(tmp_path):
    import jax

    t = _tree()
    save_pytree(tmp_path / "ck", t, step=5, extra={"note": "hi"})
    restored, manifest = restore_pytree(tmp_path / "ck", t)
    assert manifest["step"] == 5
    assert manifest["extra"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path):
    save_pytree(tmp_path / "ck", _tree(), step=1)
    with pytest.raises(ValueError, match="leaves"):
        restore_pytree(tmp_path / "ck", {"just": jnp.zeros(1)})


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in [10, 20, 30]:
        mgr.save(step, _tree())
    assert mgr.latest_step() == 30
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(), async_=True)
    mgr.wait()
    restored, manifest = mgr.restore_latest(_tree())
    assert manifest["step"] == 1


def test_crash_mid_save_keeps_previous(tmp_path):
    """A stale .tmp dir (simulated crash) must not shadow the good ckpt."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    # simulate a crashed save: partial tmp dir without manifest
    bad = tmp_path / "step_00000002.tmp"
    bad.mkdir()
    (bad / "leaves.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    restored, manifest = mgr.restore_latest(_tree())
    assert manifest["step"] == 1


def test_restore_empty_returns_none(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree, manifest = mgr.restore_latest(_tree())
    assert tree is None and manifest is None
