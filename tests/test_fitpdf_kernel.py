"""Fused fit kernels (kernels/fitpdf) vs the chained pure-jnp oracle.

Coverage per the fused-fit issue: all 10 candidate types, P not a multiple
of block_points, n not a multiple of block_obs, and degenerate windows
(constant values, vmin == vmax)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributions as d
from repro.core import fitting
from repro.core import pdf_error as pe
from repro.kernels import fitpdf

# P deliberately not multiples of block_points (8 TPU / 64 interpret), n not
# multiples of block_obs (512 TPU / 1024 interpret) nor of the 128-lane pad.
SHAPES = [(1, 64), (7, 100), (37, 513), (64, 1000), (129, 2048), (5, 1)]


def _window(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(3000.0, 10.0, shape), jnp.float32)


@pytest.mark.parametrize("shape", SHAPES)
def test_moments_and_edges_match_reference(shape):
    v = _window(shape, seed=hash(shape) % 2**31)
    m_ref = d.moments_from_values(v)
    m_k, edges_k = fitpdf.moments_and_edges(v, 20)
    for name, got, want in zip(m_ref._fields, m_k, m_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3, err_msg=name
        )
    edges_ref = pe.interval_edges(m_ref.vmin, m_ref.vmax, 20)
    np.testing.assert_allclose(
        np.asarray(edges_k), np.asarray(edges_ref), rtol=1e-6, atol=1e-3
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("types", [d.TYPES_4, d.TYPES_10], ids=["4types", "10types"])
def test_fit_errors_allclose_reference(shape, types):
    """The single-launch hist+error kernel == the chained oracle, every type."""
    v = _window(shape, seed=hash((shape, len(types))) % 2**31)
    m = d.moments_from_values(v)
    params_all = d.fit_all(types, m)
    ref = np.asarray(fitpdf.fit_errors_ref(v, m, params_all, types, 20))
    got = np.asarray(fitpdf.fit_errors(v, m, params_all, types, 20))
    # atol headroom for the gamma Wilson-Hilferty branch: its cancellation
    # term amplifies 1 ulp of f32 across compilation contexts to ~1e-4.
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=5e-4, equal_nan=True)


@pytest.mark.parametrize("num_bins", [8, 64])
def test_fit_errors_small_blocks_cover_grid_seams(num_bins):
    """Explicit tiny blocks force multi-cell grids in both axes (padding rows
    and masked obs columns must not leak into the epilogue)."""
    v = _window((13, 300), seed=3)
    m = d.moments_from_values(v)
    params_all = d.fit_all(d.TYPES_4, m)
    ref = np.asarray(fitpdf.fit_errors_ref(v, m, params_all, d.TYPES_4, num_bins))
    got = np.asarray(
        fitpdf.fit_errors(
            v, m, params_all, d.TYPES_4, num_bins, block_points=4, block_obs=128
        )
    )
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4, equal_nan=True)


def test_degenerate_constant_window():
    """vmin == vmax: same NaN pattern as the oracle (uniform's empty support),
    and the executor-level selection is finite and identical."""
    v = jnp.full((5, 100), 7.0)
    m = d.moments_from_values(v)
    assert float(m.vmin[0]) == float(m.vmax[0])
    params_all = d.fit_all(d.TYPES_10, m)
    ref = np.asarray(fitpdf.fit_errors_ref(v, m, params_all, d.TYPES_10, 16))
    got = np.asarray(fitpdf.fit_errors(v, m, params_all, d.TYPES_10, 16))
    np.testing.assert_array_equal(np.isnan(ref), np.isnan(got))
    np.testing.assert_allclose(got, ref, atol=1e-5, equal_nan=True)

    a = fitting.select_best(params_all, jnp.asarray(ref))
    b = fitting.select_best(params_all, jnp.asarray(got))
    np.testing.assert_array_equal(np.asarray(a.type_idx), np.asarray(b.type_idx))
    assert np.isfinite(np.asarray(b.error)).all()


def test_fit_errors_chained_from_kernel_edges():
    """The standalone two-launch chain: kernel-A edges feed kernel B (at most
    1-ulp from the XLA edges; errors stay allclose on the non-pathological
    types the selection actually uses)."""
    v = _window((16, 400), seed=11)
    m_k, edges_k = fitpdf.moments_and_edges(v, 20)
    params_all = d.fit_all(d.TYPES_4, m_k)
    ref = np.asarray(fitpdf.fit_errors_ref(v, m_k, params_all, d.TYPES_4, 20))
    got = np.asarray(
        fitpdf.fit_errors(v, m_k, params_all, d.TYPES_4, 20, edges=edges_k)
    )
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3, equal_nan=True)


@pytest.mark.parametrize("padded_g", [4, 16])
def test_fit_errors_row_indices_prologue(padded_g):
    """The rep-indexed gather prologue (grouping-aware dispatch): passing
    row_indices with per-representative moments/params is bitwise-identical
    to pre-gathering the value rows — including repeated and padding rows."""
    import jax

    v = _window((23, 300), seed=17)
    m = d.moments_from_values(v)
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, 23, size=padded_g), jnp.int32)
    sub_m = jax.tree.map(lambda f: f[idx], m)
    params_all = d.fit_all(d.TYPES_4, sub_m)
    got = np.asarray(
        fitpdf.fit_errors(v, sub_m, params_all, d.TYPES_4, 20, row_indices=idx)
    )
    want = np.asarray(fitpdf.fit_errors(v[idx], sub_m, params_all, d.TYPES_4, 20))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (padded_g, len(d.TYPES_4))


def test_fit_all_rows_matches_gather_then_fit():
    """fitting.fit_all_rows == gather_rows + fit_all for every backend.

    Both sides are jitted: jit-vs-eager XLA compilation differs by ~1 ulp on
    the reference chain, and the executor runs everything jitted — what must
    hold bitwise is jitted-rows vs jitted-gather-then-fit."""
    import jax

    v = _window((19, 256), seed=23)
    idx = jnp.asarray([0, 5, 5, 18, 2, 0, 7, 11], jnp.int32)
    for name in fitting.FIT_BACKENDS:
        backend = fitting.get_fit_backend(name, 16)
        m = backend.moments(v)
        rows = jax.jit(
            lambda vv, mm: fitting.fit_all_rows(
                backend, vv, mm, idx, d.TYPES_4, 16, "fused"
            )
        )(v, m)

        @jax.jit
        def direct_f(vv, mm):
            sub_v, sub_m = fitting.gather_rows(vv, mm, idx)
            return backend.fit_all(sub_v, sub_m, d.TYPES_4, 16, "fused")

        direct = direct_f(v, m)
        np.testing.assert_array_equal(
            np.asarray(rows.type_idx), np.asarray(direct.type_idx), err_msg=name
        )
        np.testing.assert_array_equal(
            np.asarray(rows.error), np.asarray(direct.error), err_msg=name
        )


def test_backend_registry_names():
    assert fitting.FIT_BACKENDS == ("reference", "kernels", "fused")
    for name in fitting.FIT_BACKENDS:
        assert fitting.get_fit_backend(name, 16).name == name
    with pytest.raises(ValueError):
        fitting.get_fit_backend("nope", 16)
